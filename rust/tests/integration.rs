//! Cross-module integration tests: full pipelines over the suite, the
//! SDD grounding path, engine equivalence at scale, MatrixMarket
//! round-trips through factorization, and the PJRT artifact round-trip
//! (skipped when `make artifacts` hasn't run).

use parac::coordinator::pipeline::{self, Method};
use parac::factor::{self, factorize, Engine, ParacOptions};
use parac::graph::suite::{Scale, SUITE};
use parac::graph::{generators, Laplacian};
use parac::ordering::Ordering;
use parac::precond::LdlPrecond;
use parac::solve::pcg::{self, PcgOptions};

fn opts(engine: Engine, ordering: Ordering) -> ParacOptions {
    ParacOptions { engine, ordering, seed: 2024, ..Default::default() }
}

#[test]
fn parac_converges_on_every_suite_matrix() {
    for e in SUITE {
        let lap = (e.build)(Scale::Tiny);
        let o = PcgOptions { tol: 1e-7, max_iter: 1500, ..Default::default() };
        let r = pipeline::run(&lap, &pipeline::parac_gpu_method(2, 5), &o, 11).unwrap();
        assert!(
            r.converged,
            "{}: rel={} iters={}",
            e.name, r.rel_residual, r.iters
        );
    }
}

#[test]
fn engines_agree_on_suite_sample() {
    for name in ["G3_circuit", "GAP-road", "com-LiveJournal", "aniso_3d_poisson"] {
        let e = parac::graph::suite::by_name(name).unwrap();
        let lap = (e.build)(Scale::Tiny);
        for ord in [Ordering::NnzSort, Ordering::Amd] {
            let fs = factorize(&lap, &opts(Engine::Seq, ord)).unwrap();
            let fc = factorize(&lap, &opts(Engine::Cpu { threads: 3 }, ord)).unwrap();
            let fg = factorize(&lap, &opts(Engine::GpuSim { blocks: 3 }, ord)).unwrap();
            assert_eq!(fs.g, fc.g, "{name}/{ord:?}: seq vs cpu");
            assert_eq!(fs.g, fg.g, "{name}/{ord:?}: seq vs gpusim");
            assert_eq!(fs.diag, fg.diag);
        }
    }
}

#[test]
fn sdd_grounding_preconditions_spd_system() {
    // Dirichlet Poisson: grid Laplacian + boundary mass → SPD SDD.
    let lap = generators::grid2d(24, 24, generators::Coeff::Uniform, 0);
    let n = lap.n();
    let mut coo = parac::sparse::Coo::new(n, n);
    for r in 0..n {
        for (&c, &v) in lap.matrix.row_indices(r).iter().zip(lap.matrix.row_data(r)) {
            coo.push(r as u32, c, v);
        }
    }
    for r in 0..24u32 {
        coo.push(r, r, 1.0); // Dirichlet top row
    }
    let a = coo.to_csr();
    let f = factor::factorize_sdd(&a, &ParacOptions::default()).unwrap();
    assert_eq!(f.n(), n);
    f.validate().unwrap();
    let pre = LdlPrecond::new(f);
    let mut rng = parac::rng::Rng::new(9);
    let xs: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
    let b = a.mul_vec(&xs);
    let o = PcgOptions { project: false, tol: 1e-10, max_iter: 400, ..Default::default() };
    let out = pcg::solve(&a, &b, &pre, &o);
    assert!(out.converged, "rel={}", out.rel_residual);
    for (got, want) in out.x.iter().zip(&xs) {
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }
}

#[test]
fn expectation_property_via_quadratic_forms() {
    // E[GDGᵀ] = L checked through quadratic forms on a graph with real
    // cliques: mean of xᵀ(GDGᵀ)x over seeds → xᵀLx.
    let lap = generators::erdos_renyi(60, 8.0, 3);
    let x = pcg::random_rhs(&lap, 1);
    let lx = parac::sparse::ops::dot(&x, &lap.matrix.mul_vec(&x));
    let trials = 600;
    let mut acc = 0.0;
    for t in 0..trials {
        let mut o = opts(Engine::Seq, Ordering::Natural);
        o.seed = 10_000 + t;
        let f = factorize(&lap, &o).unwrap();
        acc += parac::sparse::ops::dot(&x, &f.apply(&x));
    }
    let mean = acc / trials as f64;
    assert!(
        (mean - lx).abs() < 0.05 * lx.abs().max(1.0),
        "E[xᵀGDGᵀx] = {mean} vs xᵀLx = {lx}"
    );
}

#[test]
fn matrix_market_roundtrip_through_pipeline() {
    let lap = generators::delaunay_like(12, 12, 7);
    let dir = std::env::temp_dir().join("parac_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("delaunay.mtx");
    parac::sparse::mm::write_matrix_market(&lap.matrix, &path, true).unwrap();
    let back = parac::sparse::mm::read_matrix_market(&path).unwrap();
    let lap2 = Laplacian { matrix: back, kind: lap.kind, name: "roundtrip".into() };
    lap2.validate().unwrap();
    let f1 = factorize(&lap, &opts(Engine::Seq, Ordering::Natural)).unwrap();
    let f2 = factorize(&lap2, &opts(Engine::Seq, Ordering::Natural)).unwrap();
    assert_eq!(f1.g, f2.g);
}

#[test]
fn baselines_beat_identity_on_contrast_mesh() {
    let lap = generators::grid2d(20, 20, generators::Coeff::HighContrast(4.0), 5);
    let o = PcgOptions { tol: 1e-7, max_iter: 4000, ..Default::default() };
    let plain = pipeline::run(&lap, &Method::Jacobi, &o, 3).unwrap();
    for m in [
        Method::Ichol0,
        Method::IcholT { droptol: Some(1e-3), fill_target: None },
        Method::Amg,
        pipeline::parac_cpu_method(2, 4),
    ] {
        let r = pipeline::run(&lap, &m, &o, 3).unwrap();
        assert!(r.converged, "{}", r.method);
        assert!(
            r.iters <= plain.iters,
            "{} ({}) should not lose to Jacobi ({})",
            r.method, r.iters, plain.iters
        );
    }
}

#[test]
fn hlo_sampler_matches_native_reference() {
    // Requires artifacts; skip (pass vacuously) when absent.
    let Ok(mut arts) = parac::runtime::Artifacts::open_default() else {
        eprintln!("skipping: PJRT unavailable");
        return;
    };
    if !arts.available().iter().any(|n| n.starts_with("sample_")) {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    use parac::runtime::sampler::{native_reference, HloSampler, SampleTask};
    let seed = 77;
    let mut rng = parac::rng::Rng::new(3);
    let tasks: Vec<SampleTask> = (0..96)
        .map(|i| {
            let m = 2 + rng.below(14);
            let mut nbrs: Vec<(u32, f64)> = (0..m)
                .map(|j| (100 + j as u32, rng.range_f64(0.5, 8.0)))
                .collect();
            parac::factor::sample::sort_by_weight(&mut nbrs);
            SampleTask { pivot: i, nbrs }
        })
        .collect();
    let mut sampler = HloSampler::new(&mut arts, seed);
    let got = sampler.run_bucket(16, &tasks).unwrap();
    let want: Vec<_> = tasks.iter().flat_map(|t| native_reference(seed, t)).collect();
    assert_eq!(got.len(), want.len());
    let mut mismatched_partners = 0;
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.u, w.u, "source vertex");
        // f32 CDF rounding can very occasionally pick the adjacent
        // partner; weights must always match to f32 accuracy.
        if g.v != w.v {
            mismatched_partners += 1;
        }
        assert!(
            (g.w - w.w).abs() <= 1e-5 * w.w.max(1e-3),
            "weight {} vs {}",
            g.w, w.w
        );
    }
    assert!(
        mismatched_partners * 50 <= got.len(),
        "{mismatched_partners}/{} partner mismatches — beyond f32 rounding",
        got.len()
    );
}
